"""Unified observability plane: Prometheus text exposition over the
§4.6 registries, a tick/pump phase profiler, and an SLO flight recorder.

Three pieces (tentpole 2-4 of the observability PR):

- ``render_exposition`` / ``parse_exposition``: the §4.6 ``Registry``
  contents as Prometheus text exposition format — ``# TYPE`` comments,
  ``pod`` labels, full cumulative ``_bucket{le=...}`` series for
  histograms. ``serve.py --metrics-out`` dumps it; ``tools/metriclint.py``
  and the obs-smoke CI job parse it back.
- ``TickProfiler``: cheap phase-timing accumulator for the control-plane
  tick (nodes reconcile, deployment reconcile, scheduler place, audit)
  and the runtime ``pump()`` (admit, decode, retire). Surfaced per bench
  in ``BENCH_*.json`` and by ``serve.py`` at end of run.
- ``FlightRecorder``: bounded ring of recent events riding the span
  ring, with burn-rate SLO tracking over a sliding window (LC p99
  latency, shed fraction, restore latency). A threshold breach or an
  ``InvariantAuditor`` violation trips an *incident*: a JSON bundle of
  the recent spans/events (``tools/tracedump.py`` renders a timeline),
  auto-written to ``dump_dir`` when configured.
"""
from __future__ import annotations

import json
import math
import pathlib
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional

from repro.core.metrics import Counter, Gauge, Histogram, Registry, \
    split_series

# --------------------------------------------------------- exposition

def _fmt(v: float) -> str:
    if isinstance(v, float) and math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return format(float(v), ".10g")


def _merge_labels(pod: str, lbl: str, extra: str = "") -> str:
    """Combine the pod label, a metric's own rendered label block (the
    ``{k="v"}`` suffix of its series key) and an optional extra pair."""
    inner = f'pod="{pod}"'
    if lbl:
        inner += "," + lbl[1:-1]
    if extra:
        inner += "," + extra
    return "{" + inner + "}"


def render_exposition(registries: Dict[str, Registry]) -> str:
    """Prometheus text exposition of every registry, keyed by pod name.

    Histograms render the full cumulative bucket series (``_bucket`` with
    ``le`` labels) plus ``_sum``/``_count`` — the distribution the plain
    ``Registry.collect`` scrape flattens away."""
    groups: Dict[str, list] = {}          # base name -> [(type, line), ...]
    for pod in sorted(registries):
        reg = registries[pod]
        for key in sorted(reg.metrics):
            m = reg.metrics[key]
            base, lbl = split_series(key)
            if isinstance(m, Histogram):
                lines = groups.setdefault(base, [("histogram", None)])
                acc = 0
                for bound, cnt in zip(m.buckets, m.counts):
                    acc += cnt
                    lines.append((None, f"{base}_bucket"
                                  f"{_merge_labels(pod, lbl, f'le={json.dumps(_fmt(bound))}')}"
                                  f" {acc}"))
                lines.append((None, f"{base}_sum{_merge_labels(pod, lbl)}"
                              f" {_fmt(m.total)}"))
                lines.append((None, f"{base}_count{_merge_labels(pod, lbl)}"
                              f" {m.n}"))
            else:
                kind = "counter" if isinstance(m, Counter) else "gauge"
                lines = groups.setdefault(base, [(kind, None)])
                lines.append((None, f"{base}{_merge_labels(pod, lbl)}"
                              f" {_fmt(m.value)}"))
    out = []
    for base in sorted(groups):
        kind = groups[base][0][0]
        out.append(f"# TYPE {base} {kind}")
        out.extend(line for _, line in groups[base][1:])
    return "\n".join(out) + ("\n" if out else "")


def parse_exposition(text: str) -> Dict[str, float]:
    """Strict-enough parser for the exposition format above: returns
    {series-with-labels: value}; raises ValueError on a malformed line.
    Used by metriclint / the obs-smoke job to assert the dump parses."""
    out: Dict[str, float] = {}
    for i, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        # series name [+ one balanced label block], one space, a float
        j = line.find("{")
        if j >= 0:
            k = line.find("}")
            if k < j:
                raise ValueError(f"line {i}: unbalanced labels: {line!r}")
            name, rest = line[:k + 1], line[k + 1:]
        else:
            parts = line.split(" ", 1)
            if len(parts) != 2:
                raise ValueError(f"line {i}: not 'name value': {line!r}")
            name, rest = parts
        try:
            val = float(rest.strip().replace("+Inf", "inf"))
        except ValueError:
            raise ValueError(f"line {i}: bad value in {line!r}")
        if not name or not (name[0].isalpha() or name[0] == "_"):
            raise ValueError(f"line {i}: bad series name {name!r}")
        out[name] = val
    return out


# ----------------------------------------------------------- profiler

class TickProfiler:
    """Phase-timing accumulator (wall-clock, ``time.perf_counter``).

    Phases are plain string names; nesting is allowed and simply counts
    the inner phase inside the outer one (``pump.retire`` runs inside
    ``pump.admit``/``pump.decode`` — the harvest is part of both)."""

    def __init__(self):
        self.phases: Dict[str, list] = {}      # name -> [calls, total_s]

    def add(self, name: str, dt: float) -> None:
        e = self.phases.get(name)
        if e is None:
            self.phases[name] = [1, dt]
        else:
            e[0] += 1
            e[1] += dt

    @contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, time.perf_counter() - t0)

    def summary(self) -> Dict[str, dict]:
        return {k: {"calls": c, "total_s": round(t, 6),
                    "mean_us": round(t / c * 1e6, 1)}
                for k, (c, t) in sorted(self.phases.items())}


# ----------------------------------------------------- flight recorder

@dataclass
class SLOConfig:
    """Burn-rate SLO thresholds over a sliding ``window_s`` window.
    A threshold of 0 disables that objective."""
    lc_p99_s: float = 0.0        # p99 completion latency, LC tier only
    shed_frac: float = 0.0       # shed / (shed + served) fraction
    restore_s: float = 0.0       # max drain -> restore latency
    window_s: float = 300.0
    min_samples: int = 16        # latency samples needed before judging
    cooldown_s: float = 120.0    # min sim-time between trips per reason


class FlightRecorder:
    """Bounded ring of recent spans/events + burn-rate SLO tracking.

    The engine feeds it per-request outcomes (``note_latency`` /
    ``note_shed`` / ``note_served`` / ``note_restore``); the driver
    calls ``check(now)`` once per tick. When a burn rate crosses its
    SLO threshold — or ``trip`` is called directly (the
    ``InvariantAuditor`` does, before raising) — an incident bundle of
    the recent spans and events is recorded and, when ``dump_dir`` is
    set, written as JSON for ``tools/tracedump.py``."""

    def __init__(self, tracer=None, slo: Optional[SLOConfig] = None,
                 dump_dir: Optional[str] = None, cap: int = 4096):
        self.tracer = tracer
        self.slo = slo or SLOConfig()
        self.dump_dir = dump_dir
        self.events: deque = deque(maxlen=cap)      # (t, kind, detail)
        self.incidents: List[dict] = []
        self._lat: deque = deque()                  # (t, latency, priority)
        self._served: deque = deque()               # t
        self._shed: deque = deque()                 # t
        self._restores: deque = deque()             # (t, duration)
        self._last_trip: Dict[str, float] = {}

    # ------------------------------------------------------ ingestion
    def event(self, now: float, kind: str, detail: str = "") -> None:
        self.events.append((float(now), kind, detail))

    def note_latency(self, now: float, latency_s: float,
                     priority: int = 10) -> None:
        self._lat.append((float(now), float(latency_s), int(priority)))

    def note_served(self, now: float) -> None:
        self._served.append(float(now))

    def note_shed(self, now: float) -> None:
        self._shed.append(float(now))

    def note_restore(self, now: float, duration_s: float) -> None:
        self._restores.append((float(now), float(duration_s)))

    def _trim(self, now: float) -> None:
        lo = now - self.slo.window_s
        for dq in (self._served, self._shed):
            while dq and dq[0] < lo:
                dq.popleft()
        for dq in (self._lat, self._restores):
            while dq and dq[0][0] < lo:
                dq.popleft()

    # ------------------------------------------------------ burn rates
    def burn(self, now: float) -> Dict[str, float]:
        """Current burn rates over the sliding window."""
        self._trim(now)
        lc = sorted(v for _, v, p in self._lat if p >= 100)
        allv = sorted(v for _, v, _ in self._lat)
        denom = len(self._served) + len(self._shed)
        return {
            "lc_p99_s": lc[min(int(0.99 * len(lc)), len(lc) - 1)]
            if lc else 0.0,
            "p99_s": allv[min(int(0.99 * len(allv)), len(allv) - 1)]
            if allv else 0.0,
            "lc_samples": len(lc),
            "samples": len(allv),
            "shed_frac": len(self._shed) / denom if denom else 0.0,
            "restore_max_s": max((d for _, d in self._restores),
                                 default=0.0),
        }

    def check(self, now: float) -> Optional[dict]:
        """Evaluate SLOs; trip (at most one incident per call, rate
        limited per reason) when a burn rate crosses its threshold."""
        b = self.burn(now)
        slo = self.slo
        if slo.lc_p99_s > 0 and b["lc_samples"] >= slo.min_samples \
                and b["lc_p99_s"] > slo.lc_p99_s:
            return self._maybe_trip(now, "lc-p99",
                                    f"{b['lc_p99_s']:.3f}s > "
                                    f"{slo.lc_p99_s:.3f}s", b)
        if slo.shed_frac > 0 and b["samples"] >= slo.min_samples \
                and b["shed_frac"] > slo.shed_frac:
            return self._maybe_trip(now, "shed-fraction",
                                    f"{b['shed_frac']:.3f} > "
                                    f"{slo.shed_frac:.3f}", b)
        if slo.restore_s > 0 and b["restore_max_s"] > slo.restore_s:
            return self._maybe_trip(now, "restore-latency",
                                    f"{b['restore_max_s']:.3f}s > "
                                    f"{slo.restore_s:.3f}s", b)
        return None

    def _maybe_trip(self, now: float, reason: str, detail: str,
                    burn: dict) -> Optional[dict]:
        last = self._last_trip.get(reason)
        if last is not None and now - last < self.slo.cooldown_s:
            return None
        return self.trip(now, reason, detail, burn)

    # -------------------------------------------------------- incidents
    def trip(self, now: float, reason: str, detail: str = "",
             burn: Optional[dict] = None) -> dict:
        """Record an incident bundle (and write it to ``dump_dir``)."""
        self._last_trip[reason] = now
        self.event(now, "incident", f"{reason}: {detail}")
        bundle = {
            "reason": reason,
            "detail": detail,
            "t": float(now),
            "slo": asdict(self.slo),
            "burn": burn or self.burn(now),
            "events": [list(e) for e in self.events],
            "spans": self.tracer.dump() if self.tracer is not None else [],
        }
        self.incidents.append(bundle)
        if self.dump_dir:
            d = pathlib.Path(self.dump_dir)
            d.mkdir(parents=True, exist_ok=True)
            path = d / f"incident_{len(self.incidents):03d}_{reason}.json"
            path.write_text(json.dumps(bundle, indent=1))
        return bundle

    def dump(self) -> dict:
        """Full JSON-safe flight-recorder state (``serve.py
        --trace-out``): the span ring, recent events, burn rates and
        incident metadata (incident bundles carry their own spans)."""
        return {
            "spans": self.tracer.dump() if self.tracer is not None else [],
            "events": [list(e) for e in self.events],
            "slo": asdict(self.slo),
            "incidents": [{"reason": i["reason"], "detail": i["detail"],
                           "t": i["t"]} for i in self.incidents],
        }
