"""Request-lifecycle tracing (observability plane, tentpole 1).

Every ``Request`` carries a trace context stamped at the
``RequestSource`` (``Request.trace_id``), and each hop of its life
records a ``Span`` against the shared ``Tracer`` ring with monotonic
sim-time:

    enqueue -> police/shed -> admit (miss/tail/full/follow) -> prefill
    -> decode waves -> retire

plus control-plane spans (``schedule``, ``preempt``, ``drain_node``,
``checkpoint``, ``crash_restore``, ``transfer_window``), per-rid fault
spans (``drain``, ``restore``) and QoS transitions (``brownout``,
``breaker``). A single rid is reconstructable end-to-end across
replicas, sites and fault incarnations: ``Tracer.chain(rid)`` returns
its spans in emission order, and every rid-carrying span is stamped
with the rid's current *incarnation* (bumped whenever a ``restore``
span lands), so "decode on replica A, incarnation 0" and "decode on
replica B, incarnation 1" are distinguishable after a drain.

Cost model: tracing must be cheap enough to leave on (<5% tokens/s —
``bench_observability_overhead``). ``Tracer.span`` early-returns when
disabled, block-level spans (``prefill``/``decode``) carry a tuple of
rids instead of one span per request per wave, and the ring is a
bounded ``deque`` — memory is O(cap), never O(run length). Producers
hold an optional ``tracer`` attribute defaulting to ``None`` and guard
every emission with one attribute test, so the disabled path costs a
single ``is None`` branch.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class Span:
    """One hop of a request's (or the control plane's) life.

    ``seq`` is a tracer-global monotonic counter: spans emitted at the
    same sim-time (one tick) still order exactly as they happened.
    ``inc`` is the rid's fault incarnation at emission time (0 before
    any restore). Block-level spans (prefill/decode) use ``rid=0`` and
    list their member rids under ``attrs["rids"]``."""
    name: str
    t: float
    rid: int = 0
    seq: int = 0
    inc: int = 0
    attrs: Dict[str, object] = field(default_factory=dict)

    def to_dict(self) -> dict:
        attrs = {k: (list(v) if isinstance(v, tuple) else v)
                 for k, v in self.attrs.items()}
        return {"name": self.name, "t": self.t, "rid": self.rid,
                "seq": self.seq, "inc": self.inc, "attrs": attrs}


class Tracer:
    """Bounded span ring shared by every layer of the stack.

    One Tracer per engine/driver; producers (source, engine, runtimes,
    scheduler, controllers, QoS machines) all write here so ``chain``
    sees a rid's whole life regardless of which replica or site served
    each hop."""

    def __init__(self, enabled: bool = True, cap: int = 65536):
        self.enabled = enabled
        self.cap = cap
        self.spans: deque = deque(maxlen=cap)
        self.dropped = 0                      # spans evicted by the ring
        # rid -> restore count: the fault-incarnation stamp. A rid's
        # incarnation bumps when a ``restore`` span lands for it, so
        # post-restore spans carry inc = (restores seen so far).
        self.incarnations: Dict[int, int] = {}
        self._seq = 0

    def span(self, name: str, t: float, rid: int = 0, **attrs) -> None:
        if not self.enabled:
            return
        if rid and name == "restore":
            self.incarnations[rid] = self.incarnations.get(rid, 0) + 1
        self._seq += 1
        if len(self.spans) == self.cap:
            self.dropped += 1
        self.spans.append(Span(name, float(t), int(rid), self._seq,
                               self.incarnations.get(rid, 0) if rid else 0,
                               attrs))

    def chain(self, rid: int) -> List[Span]:
        """Every span of one rid, in emission order: spans stamped with
        the rid directly plus block-level spans listing it in
        ``attrs["rids"]``."""
        out = []
        for s in self.spans:
            if s.rid == rid or rid in (s.attrs.get("rids") or ()):
                out.append(s)
        return out

    def rids(self) -> List[int]:
        seen = set()
        for s in self.spans:
            if s.rid:
                seen.add(s.rid)
            seen.update(s.attrs.get("rids") or ())
        return sorted(seen)

    def dump(self) -> List[dict]:
        """JSON-safe snapshot of the ring (flight-recorder bundles)."""
        return [s.to_dict() for s in self.spans]


#: Shared disabled tracer: safe default for call sites that want to
#: emit unconditionally (``NULL_TRACER.span(...)`` is a no-op).
NULL_TRACER = Tracer(enabled=False)
