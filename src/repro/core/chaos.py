"""Chaos fault-injection subsystem — deterministic, seed-driven.

JIRIAF's pitch is unified control over pilot allocations on facilities
the operator does not own: walltime kills, stale heartbeats, network
partitions, and flaky filesystems are the steady state, not the
exception. This module makes those failures *first-class, reproducible
inputs* so every recovery path in the control plane is exercised under
test and bench instead of assumed.

Design rules:

  * **Seams, not monkey-patching.** Every fault lands through an
    existing public surface: heartbeats are simply not driven (crash),
    ``Cluster.set_reachable`` flips the API-server boundary (partition),
    ``Cluster.set_node_status`` is the JFM feed path (flap),
    heartbeat latency inflation rides ``FacilityManager.scrape``'s
    straggler detection, ``VirtualNode.cut_walltime`` revises the lease,
    and checkpoint corruption edits bytes on disk exactly like a failing
    filesystem would.
  * **Deterministic.** The schedule is declarative (`FaultSpec` list or
    the ``kind:target@at[+duration][x<mag>]`` string form used by
    ``--chaos``); ``"*"`` targets resolve via a seeded RNG. Two runs
    with the same seed and schedule inject byte-identical faults.
  * **Audited.** ``InvariantAuditor`` checks the quota-ledger books,
    every paged runtime's allocator refcount books, and slot-table/rid
    exactly-once accounting every tick while chaos runs — a fault that
    silently corrupts accounting fails immediately, not at the end.

Driver contract (see ``bench_chaos_soak`` and ``launch/serve.py``): in
place of the plain per-tick ``cluster.heartbeat`` loop + ``fm.feed``,
call ``injector.apply(cluster, now, fm=fm)``.
"""
from __future__ import annotations

import pathlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.cluster import KIND_NODE, Cluster

# fault kinds
CRASH = "crash"              # heartbeats stop forever (process gone)
FLAP = "flap"                # NotReady<->Ready oscillation via the JFM seam
PARTITION = "partition"      # unreachable, alive; rejoins after duration
STRAGGLER = "straggler"      # heartbeat latency inflated by `magnitude`
CKPT_CORRUPT = "ckpt_corrupt"  # truncate newest checkpoint generation
WALLTIME_CUT = "walltime_cut"  # lease revised to `magnitude` seconds left
SURGE = "surge"              # flash crowd: arrival rate x `magnitude`

KINDS = (CRASH, FLAP, PARTITION, STRAGGLER, CKPT_CORRUPT, WALLTIME_CUT,
         SURGE)


@dataclass(frozen=True)
class FaultSpec:
    """One declarative fault: what, who, when, for how long, how hard."""
    kind: str
    at: float                    # injection time (sim seconds)
    target: str = "*"            # node (pod for ckpt_corrupt); "*" = seeded
    duration: float = 0.0        # flap/partition/straggler window
    magnitude: float = 0.0       # straggler factor | walltime secs left

    @staticmethod
    def parse(text: str) -> "FaultSpec":
        """``kind:target@at[+duration][x<magnitude>]`` — the ``--chaos``
        flag's form, e.g. ``partition:n0@120+45`` or
        ``straggler:*@60+30x8`` or ``walltime_cut:n2@100x70``."""
        head, _, when = text.partition("@")
        kind, _, target = head.partition(":")
        if kind not in KINDS:
            raise ValueError(f"unknown fault kind {kind!r} (have {KINDS})")
        if not when:
            raise ValueError(f"fault {text!r} needs @<time>")
        mag = 0.0
        if "x" in when:
            when, _, m = when.partition("x")
            mag = float(m)
        dur = 0.0
        if "+" in when:
            when, _, d = when.partition("+")
            dur = float(d)
        return FaultSpec(kind=kind, at=float(when), target=target or "*",
                         duration=dur, magnitude=mag)


@dataclass
class _Active:
    spec: FaultSpec
    target: str
    until: float


class ChaosInvariantError(AssertionError):
    """An every-tick invariant broke while chaos was running."""


@dataclass
class FaultInjector:
    """Applies a declarative fault schedule through control-plane seams.

    ``apply(cluster, now, fm=...)`` replaces the driver's heartbeat +
    JFM feed block: it fires due faults, drives heartbeats for every
    node that can still send them (with straggler latency inflation),
    runs the facility manager's feed, then overlays flap reports."""
    schedule: List[FaultSpec] = field(default_factory=list)
    seed: int = 0
    ckpt_dir: Optional[str] = None      # where ckpt_corrupt finds pod dirs
    base_latency: float = 1.0           # healthy heartbeat latency
    log: List[Tuple[float, str, str]] = field(default_factory=list)
    crashed: Set[str] = field(default_factory=set)
    _active: List[_Active] = field(default_factory=list)
    _fired: Set[int] = field(default_factory=set)
    _rng: np.random.Generator = None

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.schedule = [FaultSpec.parse(s) if isinstance(s, str) else s
                         for s in self.schedule]

    # ------------------------------------------------------------ state
    def _windows(self, kind: str) -> List[_Active]:
        return [a for a in self._active if a.spec.kind == kind]

    def is_partitioned(self, name: str) -> bool:
        return any(a.target == name for a in self._windows(PARTITION))

    def is_flapping(self, name: str) -> bool:
        return any(a.target == name for a in self._windows(FLAP))

    def straggler_factor(self, name: str) -> float:
        f = [a.spec.magnitude or 8.0 for a in self._windows(STRAGGLER)
             if a.target == name]
        return max(f) if f else 1.0

    def surge_factor(self, owner: str = "*") -> float:
        """Arrival-rate multiplier for ``owner``'s request stream right
        now (product over active surge windows whose target is the owner
        or ``"*"``). Drivers wire this into the real `RequestSource`
        seam each tick: ``eng.source.surge = inj.surge_factor(owner)``."""
        f = 1.0
        for a in self._windows(SURGE):
            if a.target in ("*", owner):
                f *= (a.spec.magnitude or 2.0)
        return f

    def _note(self, now: float, kind: str, target: str):
        self.log.append((now, kind, target))

    def _pick_node(self, cluster: Cluster) -> Optional[str]:
        cands = sorted(n for n in cluster.nodes
                       if n not in self.crashed and not any(
                           a.target == n for a in self._active))
        if not cands:
            return None
        return cands[int(self._rng.integers(len(cands)))]

    def _pick_pod_dir(self) -> Optional[pathlib.Path]:
        if self.ckpt_dir is None:
            return None
        dirs = sorted(d for d in pathlib.Path(self.ckpt_dir).iterdir()
                      if d.is_dir() and list(d.glob("step_*")))
        if not dirs:
            return None
        return dirs[int(self._rng.integers(len(dirs)))]

    # ------------------------------------------------------------ fire
    def _fire(self, i: int, spec: FaultSpec, cluster: Cluster, now: float):
        self._fired.add(i)
        target = spec.target
        if spec.kind == SURGE:
            # target is a request-stream *owner* (or "*" for every
            # stream), not a node — skip node resolution entirely
            self._note(now, SURGE, target)
            cluster.record(now, KIND_NODE, target, "ChaosInjected",
                           f"kind={SURGE} duration={spec.duration:.0f} "
                           f"magnitude={spec.magnitude:g}")
            self._active.append(_Active(spec, target, now + spec.duration))
            return
        if spec.kind == CKPT_CORRUPT:
            pod_dir = (pathlib.Path(self.ckpt_dir) / target
                       if self.ckpt_dir and target != "*"
                       else self._pick_pod_dir())
            if pod_dir is not None and pod_dir.exists():
                hit = corrupt_latest_generation(pod_dir)
                if hit is not None:
                    self._note(now, CKPT_CORRUPT, str(hit))
                    cluster.record(now, KIND_NODE, pod_dir.name,
                                   "ChaosCkptCorrupt", f"file={hit}")
            return
        if target == "*":
            target = self._pick_node(cluster)
            if target is None:
                return
        if target not in cluster.nodes:
            # a typo'd node name must not silently disarm the fault
            self._note(now, f"{spec.kind}_skipped", target)
            return
        self._note(now, spec.kind, target)
        cluster.record(now, KIND_NODE, target, "ChaosInjected",
                       f"kind={spec.kind} duration={spec.duration:.0f} "
                       f"magnitude={spec.magnitude:g}")
        if spec.kind == CRASH:
            self.crashed.add(target)
        elif spec.kind == PARTITION:
            cluster.set_reachable(target, now, False)
            self._active.append(_Active(spec, target, now + spec.duration))
        elif spec.kind in (FLAP, STRAGGLER):
            self._active.append(_Active(spec, target, now + spec.duration))
        elif spec.kind == WALLTIME_CUT:
            # through the store seam, not node.cut_walltime directly: the
            # revised lease must reach event-driven subscribers (the
            # lifecycle controller's deadline heap) as a Node delta
            cluster.cut_walltime(target, now, spec.magnitude)

    def _expire(self, cluster: Cluster, now: float):
        still = []
        for a in self._active:
            if now < a.until:
                still.append(a)
                continue
            if a.spec.kind == PARTITION and a.target in cluster.node_status:
                cluster.set_reachable(a.target, now, True)  # rejoin
            self._note(now, f"{a.spec.kind}_end", a.target)
        self._active = still

    # ------------------------------------------------------------ apply
    def apply(self, cluster: Cluster, now: float, fm=None):
        """One chaos tick: fire due faults, expire elapsed windows, drive
        heartbeats through the normal path (crashed nodes stay silent,
        partitioned nodes are dropped at the API-server boundary,
        stragglers report inflated latency), feed the JFM scrape, then
        overlay flap NotReady reports through the same feed seam."""
        for i, spec in enumerate(self.schedule):
            if i not in self._fired and spec.at <= now:
                self._fire(i, spec, cluster, now)
        self._expire(cluster, now)
        for name in sorted(cluster.nodes):
            if name in self.crashed:
                continue
            cluster.heartbeat(
                name, now,
                latency=self.base_latency * self.straggler_factor(name))
        if fm is not None:
            fm.feed(cluster, now)
        for a in self._windows(FLAP):
            st = cluster.node_status.get(a.target)
            if st is not None and st.reachable:
                # flaky kubelet: reports NotReady with fresh heartbeats —
                # the controller must wait out stale_after, not evict
                cluster.set_node_status(
                    a.target, now, ready=False,
                    heartbeat_age=st.heartbeat_age,
                    heartbeat_latency=st.heartbeat_latency)


def corrupt_latest_generation(pod_dir, frac: float = 0.5) -> Optional[str]:
    """Truncate the newest generation's ``leaves.npz`` to ``frac`` of its
    size — what a crashed writer or a flaky filesystem leaves behind.
    Returns the damaged file's path (or None when nothing to damage)."""
    steps = sorted(pathlib.Path(pod_dir).glob("step_*"))
    if not steps:
        return None
    f = steps[-1] / "leaves.npz"
    if not f.exists():
        return None
    data = f.read_bytes()
    f.write_bytes(data[:max(1, int(len(data) * frac))])
    return str(f)


@dataclass
class InvariantAuditor:
    """Every-tick bookkeeping audit while chaos runs (tentpole (d)).

    Checks three ledgers and raises ``ChaosInvariantError`` (with tick
    context) the moment any goes out of balance:

      1. quota ledger: per-node used+free == capacity and per-owner sums
         match node truth (``QuotaLedger.assert_balanced``);
      2. page-allocator refcount books per paged runtime: used+free ==
         pool, the null page is never granted, live-page count matches
         ``used_pages``, free-list entries all have refcount 0;
      3. rid exactly-once: no rid completes twice, and no rid is queued
         or in-flight in two places at once.
    """
    cluster: Cluster
    engine: Optional[object] = None          # StreamEngine (or None)
    checks: int = 0
    # optional FlightRecorder: a violation dumps an incident bundle
    # (recent spans + events) *before* the raise tears the run down
    recorder: Optional[object] = None

    def _fail(self, now: float, what: str):
        if self.recorder is not None:
            self.recorder.trip(now, "invariant", what)
        raise ChaosInvariantError(f"[t={now:.1f}] {what}")

    def audit(self, now: float) -> Dict[str, float]:
        self.checks += 1
        # Orphans on the far side of a partition (fence-pending) were
        # evicted from the store but still physically hold resources on
        # their node until fence_node reclaims them on rejoin — the one
        # legitimate divergence between owner books and node truth. An
        # orphan anywhere else is a real leak.
        sever: Dict[str, list] = {}
        for name, st in self.cluster.node_status.items():
            if not st.reachable or name in self.cluster.fence_epochs:
                sever[name] = self.cluster.orphaned_pods(name)
        for name in self.cluster.nodes:
            if name in sever:
                continue
            stray = self.cluster.orphaned_pods(name)
            if stray:
                self._fail(now, f"{name}: orphaned pods "
                                f"{[p.name for p in stray]} on a healthy, "
                                f"fence-clear node")
        orphan_chips = sum(p.request_chips
                           for pods in sever.values() for p in pods)
        orphan_hbm = sum(p.request_hbm_bytes
                         for pods in sever.values() for p in pods)
        if orphan_chips or orphan_hbm:
            led = self.cluster.ledger
            owners = {rec.owner for rec in led._live()}
            owner_chips = sum(led.usage(o).chips for o in owners)
            owner_hbm = sum(led.usage(o).hbm_bytes for o in owners)
            node_chips = sum(n.used_chips()
                             for n in self.cluster.nodes.values())
            node_hbm = sum(n.used_hbm()
                           for n in self.cluster.nodes.values())
            if owner_chips + orphan_chips != node_chips or \
                    owner_hbm + orphan_hbm != node_hbm:
                self._fail(now, "books off beyond the severed footprint: "
                                f"owner {owner_chips} + orphan "
                                f"{orphan_chips} vs node {node_chips} chips")
            totals = {"chips_used": node_chips, "hbm_used": node_hbm,
                      "orphaned_chips": orphan_chips}
        else:
            try:
                totals = self.cluster.ledger.assert_balanced()
            except ValueError as e:
                self._fail(now, f"quota ledger: {e}")
        out = {"nodes": len(self.cluster.nodes), **{
            f"ledger_{k}": v for k, v in totals.items()
            if isinstance(v, (int, float))}}
        if self.engine is None:
            return out
        eng = self.engine
        for name, rt in eng.runtimes.items():
            alloc = getattr(rt, "alloc", None)
            if alloc is None:
                continue
            if alloc.used_pages + alloc.free_pages != alloc.pool_pages:
                self._fail(now, f"{name}: used({alloc.used_pages}) + "
                                f"free({alloc.free_pages}) != "
                                f"pool({alloc.pool_pages})")
            if alloc.refcount[0] != 0:
                self._fail(now, f"{name}: null page granted "
                                f"(refcount[0]={alloc.refcount[0]})")
            live = int(np.sum(alloc.refcount[1:] > 0))
            if live != alloc.used_pages:
                self._fail(now, f"{name}: {live} live pages vs "
                                f"used_pages={alloc.used_pages}")
            bad_free = [p for p in alloc._free if alloc.refcount[p] != 0]
            if bad_free:
                self._fail(now, f"{name}: free-list pages with live "
                                f"refcounts: {bad_free[:4]}")
        done = [rid for rid, _ in eng.completed]
        if len(done) != len(set(done)):
            dupes = sorted({r for r in done if done.count(r) > 1})
            self._fail(now, f"duplicate completion for rids {dupes[:6]}")
        seen: Dict[int, str] = {}
        for r in eng.queue:
            if r.rid in seen:
                self._fail(now, f"rid {r.rid} queued twice")
            seen[r.rid] = "queue"
        for name, rt in eng.runtimes.items():
            if not eng._node_reachable(name):
                continue        # far side of a partition: not ours anymore
            rids = [r.rid for r in rt.pending] + \
                   [s.req.rid for s in rt.slots if s.busy]
            for rid in rids:
                if rid in seen:
                    self._fail(now, f"rid {rid} in {name} AND {seen[rid]}")
                seen[rid] = name
        out["inflight"] = len(seen)
        out["completed"] = len(done)
        return out
