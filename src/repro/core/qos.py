"""QoS subsystem: priority classes and fair-share quotas (paper §3-§4).

The control plane so far treated every pod as equal — a batch backfill
job and a latency-critical ERSAP serving replica competed on identical
terms. On a shared, walltime-bounded HPC allocation that is the wrong
default: multi-tenant scientific Kubernetes deployments (NRP and
friends) make priority + fair-share the load-bearing mechanism. This
module adds the two object kinds the rest of the plane consumes:

- ``PriorityClass`` — a named scheduling tier (k8s PriorityClass
  analog). Pods carry the class name; the store resolves it to a
  numeric ``value`` (queue order, preemption order) and a
  ``preemptible`` bit (whether pods of this class may ever be evicted
  for a higher-priority pod — the victim-side half of k8s
  ``preemptionPolicy``).
- ``Quota`` — a per-owner (Deployment ≈ tenant) fair-share cap over
  chips, HBM bytes and KV pages, optionally scoped to one site. The
  scheduler enforces it as a filter stage (``filter_quota``); the
  ``QuotaLedger`` below is the accounting: usage is derived from the
  store's bound pods (never tracked imperatively), so the books cannot
  drift — ``used + free == capacity`` is checkable every tick.

Consumers: ``cluster.py`` stores both kinds and resolves classes at
submit; ``scheduler.py`` orders the queue by (priority, fair-share
ratio, age) and preempts strictly-lower-priority preemptible victims;
``hpa.py`` / ``digital_twin/control.py`` write the serving
Deployment's priority during pressure spikes; ``launch/serve.py``
parses ``--quota`` specs through :func:`parse_quotas`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.state_machine import PodPhase


@dataclass(frozen=True)
class PriorityClass:
    """A named scheduling tier. ``value`` orders the pending queue and
    bounds preemption (a pod may only evict strictly-lower values);
    ``preemptible=False`` exempts pods of this class from ever being
    preemption victims (they still drain on walltime — §4.5.4 is a
    lease expiring, not a scheduling decision)."""
    name: str
    value: int
    preemptible: bool = True
    description: str = ""


# Default tiers (k8s ships system-* classes; the rest mirror the mixed
# workload of the paper: latency-critical ERSAP serving next to
# preemptible batch science).
BATCH = PriorityClass("batch", 0, True,
                      "preemptible backfill: first evicted under pressure")
STANDARD = PriorityClass("standard", 10, True,
                         "default tier for serving and interactive work")
LATENCY_CRITICAL = PriorityClass("latency-critical", 100, True,
                                 "pressure-spike serving: preempts batch")
SYSTEM = PriorityClass("system", 1000, False,
                       "control-plane components: never preempted")

DEFAULT_PRIORITY_CLASSES = (BATCH, STANDARD, LATENCY_CRITICAL, SYSTEM)


def default_priority_classes() -> Dict[str, PriorityClass]:
    return {c.name: c for c in DEFAULT_PRIORITY_CLASSES}


@dataclass(frozen=True)
class Quota:
    """Fair-share cap for one owner (Deployment ≈ tenant). ``None``
    limits are unconstrained; ``site=None`` scopes the cap to the whole
    cluster, a site name to that facility's pool only. ``kv_pages``
    caps the *declared* per-replica KV page pools
    (``PodRecord.request_kv_pages``) — the serving runtime's
    memory-footprint currency — so a tenant cannot grab the whole
    paged-slab budget by scaling replicas."""
    owner: str
    site: Optional[str] = None
    chips: Optional[int] = None
    hbm_bytes: Optional[int] = None
    kv_pages: Optional[int] = None

    @property
    def key(self) -> Tuple[str, Optional[str]]:
        return (self.owner, self.site)


@dataclass
class Usage:
    """One owner's booked resources (bound, non-terminal pods)."""
    chips: int = 0
    hbm_bytes: int = 0
    kv_pages: int = 0
    pods: int = 0


def parse_quotas(spec: str) -> List[Quota]:
    """Parse a CLI quota spec: comma-separated entries of
    ``owner[@site]:resource=value[:resource=value...]`` with resources
    ``chips``, ``hbm_gb`` and ``kv_pages`` —
    e.g. ``"ersap:chips=8:kv_pages=1024,batch@jlab:chips=4"``."""
    out: List[Quota] = []
    for entry in spec.split(","):
        entry = entry.strip()
        if not entry:
            continue
        head, *fields = entry.split(":")
        owner, _, site = head.partition("@")
        if not fields:
            raise ValueError(f"quota entry {entry!r} names no resource")
        limits: Dict[str, Optional[int]] = {}
        for f in fields:
            key, _, val = f.partition("=")
            if key == "chips":
                limits["chips"] = int(val)
            elif key == "hbm_gb":
                limits["hbm_bytes"] = int(float(val) * 1024**3)
            elif key == "kv_pages":
                limits["kv_pages"] = int(val)
            else:
                raise ValueError(f"unknown quota resource {key!r} in "
                                 f"{entry!r} (chips|hbm_gb|kv_pages)")
        out.append(Quota(owner=owner, site=site or None, **limits))
    return out


class BatchTenant:
    """Driver-side bookkeeping for a preemptible batch tenant: a
    Deployment of single-chip pods whose only runtime state is a
    progress counter, checkpointed through the §4.5.4 / preemption path.
    One implementation of the checkpoint round-trip protocol shared by
    ``launch/serve.py --batch-load``, ``bench_priority_spike`` and the
    QoS tests — so the payload shape cannot silently diverge between
    the demo driver and the thing CI asserts on.

    ``advance()`` once per driver tick: pods make one unit of progress
    while bound; an evicted pod's live counter is dropped (the watch
    hook snapshots what the checkpoint saw), so a resumed pod *must*
    recover its progress from ``restored_state``. Each resume is
    compared against its own eviction's snapshot at adoption time (the
    snapshot is consumed, so a pod preempted twice is validated per
    cycle, not against its latest eviction): ``resumed`` is the
    round-trip evidence, ``mismatches`` must stay empty."""

    def __init__(self, cluster, replicas: int, *, name: str = "batch",
                 priority_class: str = "batch", request_chips: int = 1,
                 now: float = 0.0):
        # deferred: cluster.py imports this module for the object model
        from repro.core.cluster import (DELETED, KIND_POD, Deployment,
                                        PodTemplate)
        self.cluster = cluster
        self.name = name
        self.counters: Dict[str, int] = {}       # live progress per pod
        self.snapshots: Dict[str, int] = {}      # progress at eviction,
        #                                          consumed on resume
        self.resumed: List[Tuple[str, int]] = []  # (pod, restored progress)
        # (pod, restored, expected) where restored != snapshot
        self.mismatches: List[Tuple[str, int, int]] = []
        self._deleted = DELETED
        cluster.watch(KIND_POD, self._on_pod)
        cluster.apply_deployment(Deployment(
            name, replicas, template=PodTemplate(
                labels={"app": name},
                tolerations=[{"key": "virtual-kubelet.io/provider",
                              "value": "mock"}],
                request_chips=request_chips, priority_class=priority_class,
                checkpoint_state=self.checkpoint_state)), now)

    def checkpoint_state(self, pod_name: str) -> dict:
        """The checkpoint payload (PodTemplate.checkpoint_state hook)."""
        return {"progress": self.counters.get(pod_name, 0)}

    def _on_pod(self, ev) -> None:
        if ev.type == self._deleted and \
                getattr(ev.obj, "owner", None) == self.name:
            self.snapshots[ev.name] = self.counters.pop(ev.name, 0)

    def advance(self) -> None:
        """One driver tick: adopt restored counters for pods back from a
        checkpoint (validated against that eviction's snapshot), then
        advance every bound pod's progress."""
        for rec in self.cluster.pods_of(self.name):
            if not rec.bound:
                continue
            if rec.name not in self.counters:
                restored = int((rec.restored_state or {}).get("progress", 0))
                expected = self.snapshots.pop(rec.restored_from or rec.name,
                                              None)
                if rec.restored_from is not None:
                    self.resumed.append((rec.name, restored))
                    if expected is not None and restored != expected:
                        self.mismatches.append(
                            (rec.name, restored, expected))
                self.counters[rec.name] = restored
            self.counters[rec.name] += 1

    @property
    def bound(self) -> int:
        return sum(1 for r in self.cluster.pods_of(self.name) if r.bound)

    @property
    def total_progress(self) -> int:
        return sum(self.counters.values())


class QuotaLedger:
    """Fair-share accounting over the cluster store.

    Usage is *derived* from the store (bound, non-terminal pods) and
    memoized until a relevant watch delta arrives, so every preempt ->
    requeue -> reschedule cycle re-balances the books automatically —
    there is no imperative counter that could leak. The ledger
    subscribes to Pod and Node deltas and marks itself dirty on any of
    them except heartbeats (which change no usage), so at 10k-node
    scale the per-tick heartbeat storm no longer invalidates the cache
    the way version-keyed memoization did. ``assert_balanced`` makes
    the invariant checkable per tick: per-owner books must sum exactly
    to the node-side truth, and node ``used + free == capacity``."""

    def __init__(self, cluster):
        self.cluster = cluster
        self._cache: Dict[Tuple, Usage] = {}
        self._dirty = True
        # deferred import: cluster.py imports this module at load time
        from repro.core import cluster as _c
        cluster.watch(_c.KIND_POD, self._on_delta)
        cluster.watch(_c.KIND_NODE, self._on_delta)

    def _on_delta(self, ev) -> None:
        if ev.reason != "heartbeat":
            self._dirty = True

    def _live(self):
        for rec in self.cluster.pods.values():
            if not rec.bound:
                continue
            if rec.pod.phase in (PodPhase.SUCCEEDED, PodPhase.FAILED):
                continue
            yield rec

    def usage(self, owner: Optional[str],
              site: Optional[str] = None) -> Usage:
        if self._dirty:
            self._cache.clear()
            self._dirty = False
        key = (owner, site)
        cached = self._cache.get(key)
        if cached is not None:
            return cached
        u = Usage()
        for rec in self._live():
            if rec.owner != owner:
                continue
            if site is not None:
                node = self.cluster.nodes.get(rec.pod.node)
                if node is None or node.site != site:
                    continue
            u.chips += rec.pod.request_chips
            u.hbm_bytes += rec.pod.request_hbm_bytes
            u.kv_pages += rec.request_kv_pages
            u.pods += 1
        self._cache[key] = u
        return u

    # ------------------------------------------------------ enforcement
    def check(self, rec, node) -> Optional[str]:
        """Scheduler filter-stage body: would binding ``rec`` to ``node``
        take its owner over any applicable quota? Returns the reject
        reason, or None when within bounds / unconstrained."""
        if rec.owner is None or not self.cluster.quotas:
            return None
        for quota in (self.cluster.quota_for(rec.owner, node.site),
                      self.cluster.quota_for(rec.owner, None)):
            if quota is None:
                continue
            u = self.usage(rec.owner, quota.site)
            for limit, used, req, label in (
                    (quota.chips, u.chips, rec.pod.request_chips, "chips"),
                    (quota.hbm_bytes, u.hbm_bytes,
                     rec.pod.request_hbm_bytes, "hbm"),
                    (quota.kv_pages, u.kv_pages,
                     rec.request_kv_pages, "kv_pages")):
                if limit is not None and used + req > limit:
                    scope = f"site {quota.site}" if quota.site else "cluster"
                    return (f"quota: {rec.owner} {label} "
                            f"{used}+{req}>{limit} ({scope})")
        return None

    def dominant_share(self, owner: Optional[str]) -> float:
        """Dominant-resource share of the owner's cluster-wide quota
        (DRF-style): the scheduler orders equal-priority pending pods by
        this, so the tenant furthest below its fair share binds first.
        Unquota'd owners rank as 0 (nothing to be fair against)."""
        if owner is None:
            return 0.0
        quota = self.cluster.quota_for(owner, None)
        if quota is None:
            return 0.0
        u = self.usage(owner)
        shares = [used / limit for limit, used in
                  ((quota.chips, u.chips), (quota.hbm_bytes, u.hbm_bytes),
                   (quota.kv_pages, u.kv_pages)) if limit]
        return max(shares, default=0.0)

    # -------------------------------------------------------- invariant
    def assert_balanced(self) -> Dict[str, int]:
        """Quota books balance: per-owner usage sums to the node-side
        truth and node used + free == capacity, for chips and HBM.
        Raises ValueError with the discrepancy; returns the totals."""
        nodes = self.cluster.nodes.values()
        cap_chips = sum(n.slice_spec.chips for n in nodes)
        used_chips = sum(n.used_chips() for n in nodes)
        free_chips = sum(n.free_chips() for n in nodes)
        cap_hbm = sum(n.slice_spec.hbm_bytes for n in nodes)
        used_hbm = sum(n.used_hbm() for n in nodes)
        free_hbm = sum(n.free_hbm() for n in nodes)
        if used_chips + free_chips != cap_chips or \
                used_hbm + free_hbm != cap_hbm:
            raise ValueError(
                f"node books off: chips {used_chips}+{free_chips}"
                f"!={cap_chips} or hbm {used_hbm}+{free_hbm}!={cap_hbm}")
        owners = {rec.owner for rec in self._live()}
        owner_chips = sum(self.usage(o).chips for o in owners)
        owner_hbm = sum(self.usage(o).hbm_bytes for o in owners)
        if owner_chips != used_chips or owner_hbm != used_hbm:
            raise ValueError(
                f"ledger books off: owner chips {owner_chips} != node "
                f"chips {used_chips} (hbm {owner_hbm} vs {used_hbm})")
        return {"chips_capacity": cap_chips, "chips_used": used_chips,
                "chips_free": free_chips, "hbm_used": used_hbm,
                "hbm_free": free_hbm}


# --------------------------------------------------------------------------
# Overload protection: brownout levels, retry budgets, replica breakers.
# --------------------------------------------------------------------------

def tier_label(priority: int) -> str:
    """Map a numeric request priority to the name of the highest default
    PriorityClass at or below it (the tenant label retry budgets key on)."""
    best = BATCH
    for cls in DEFAULT_PRIORITY_CLASSES:
        if cls.value <= priority and cls.value >= best.value:
            best = cls
    return best.name


def shed_floor_for_level(level: int) -> int:
    """Minimum admitted ``Request.priority`` at a brownout level.

    Level 0 (normal) and 1 (degrade-only: cap max_new, spec decode off)
    shed nothing; level 2 sheds the batch tier (< standard); level 3
    sheds everything below latency-critical. Latency-critical traffic is
    never shed by brownout — only an explicit deadline can drop it."""
    if level <= 1:
        return 0
    if level == 2:
        return STANDARD.value
    return LATENCY_CRITICAL.value


@dataclass
class BrownoutController:
    """Watermark + hysteresis brownout state machine (tentpole b).

    Pressure each tick is ``max(slab occupancy, queue-delay EWMA /
    delay_target_s)``. Sustained pressure >= ``high_water`` for
    ``dwell_ticks`` consecutive ticks escalates one level; sustained
    pressure <= ``low_water`` for ``recover_ticks`` de-escalates one
    level (staged recovery — a momentarily empty queue cannot snap the
    system from level 3 to 0 and instantly re-trigger). The band between
    the watermarks holds the current level and resets both counters, so
    oscillation around a single watermark cannot flap the level."""
    high_water: float = 0.85
    low_water: float = 0.5
    delay_target_s: float = 30.0
    ewma_alpha: float = 0.4
    dwell_ticks: int = 2
    recover_ticks: int = 3
    max_level: int = 3
    degrade_max_new: int = 8
    # state
    level: int = 0
    delay_ewma: float = 0.0
    last_pressure: float = 0.0
    transitions: List[Tuple[float, int, int, float]] = field(
        default_factory=list)        # (now, old, new, pressure)
    tracer: object = None            # optional: spans at level changes
    _over: int = 0
    _under: int = 0

    def update(self, now: float, occupancy: float,
               queue_delay_s: float) -> int:
        self.delay_ewma += self.ewma_alpha * (queue_delay_s - self.delay_ewma)
        p = max(occupancy,
                self.delay_ewma / max(self.delay_target_s, 1e-9))
        self.last_pressure = p
        if p >= self.high_water:
            self._over += 1
            self._under = 0
        elif p <= self.low_water:
            self._under += 1
            self._over = 0
        else:                        # hysteresis dead band: hold level
            self._over = 0
            self._under = 0
        if self._over >= self.dwell_ticks and self.level < self.max_level:
            self.transitions.append((now, self.level, self.level + 1, p))
            if self.tracer is not None:
                self.tracer.span("brownout", now, old=self.level,
                                 new=self.level + 1, pressure=round(p, 4))
            self.level += 1
            self._over = 0
        elif self._under >= self.recover_ticks and self.level > 0:
            self.transitions.append((now, self.level, self.level - 1, p))
            if self.tracer is not None:
                self.tracer.span("brownout", now, old=self.level,
                                 new=self.level - 1, pressure=round(p, 4))
            self.level -= 1
            self._under = 0
        return self.level

    def shed_floor(self) -> int:
        return shed_floor_for_level(self.level)

    def max_new_cap(self) -> Optional[int]:
        """Output-length cap while degraded (level >= 1), else None."""
        return self.degrade_max_new if self.level >= 1 else None

    def spec_enabled(self) -> bool:
        """Speculative decode is a throughput luxury: off while degraded."""
        return self.level == 0


@dataclass
class RetryBudget:
    """Per-tenant token-bucket retry budgets (tentpole c).

    Each backpressured retry costs one token from the tenant's bucket
    (refill ``rate``/s up to ``burst``). When the bucket is dry the
    retry is shed instead of re-queued, so client retries cannot
    amplify an overload incident into a retry storm."""
    rate: float = 0.5
    burst: float = 10.0
    granted: int = 0
    denied: int = 0
    _buckets: Dict[str, Tuple[float, float]] = field(default_factory=dict)

    def allow(self, tenant: str, now: float) -> bool:
        tokens, last = self._buckets.get(tenant, (self.burst, now))
        tokens = min(self.burst, tokens + max(now - last, 0.0) * self.rate)
        ok = tokens >= 1.0
        if ok:
            tokens -= 1.0
            self.granted += 1
        else:
            self.denied += 1
        self._buckets[tenant] = (tokens, now)
        return ok

    def tokens(self, tenant: str, now: float) -> float:
        t, last = self._buckets.get(tenant, (self.burst, now))
        return min(self.burst, t + max(now - last, 0.0) * self.rate)


BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half-open"


@dataclass
class ReplicaBreaker:
    """Per-replica circuit breaker (tentpole c).

    A replica that takes work but emits zero tokens for ``stall_ticks``
    consecutive ticks (or reports errors) is *ejected*: the engine
    routes around it entirely. After ``probe_after_s`` the breaker goes
    half-open and admits up to ``probe_budget`` probe requests; a
    healthy probe closes the breaker (rejoin), a stalled probe re-opens
    it for another cool-off."""
    stall_ticks: int = 3
    probe_after_s: float = 30.0
    probe_budget: int = 2
    ejections: int = 0
    rejoins: int = 0
    tracer: object = None            # optional: spans at state changes
    _state: Dict[str, str] = field(default_factory=dict)
    _stall: Dict[str, int] = field(default_factory=dict)
    _opened_at: Dict[str, float] = field(default_factory=dict)
    _probes: Dict[str, int] = field(default_factory=dict)

    def state(self, name: str) -> str:
        return self._state.get(name, BREAKER_CLOSED)

    def allow(self, name: str, now: float) -> int:
        """How many requests ``name`` may take this tick: -1 unbounded
        (closed), 0 none (open, still cooling off), or the remaining
        probe budget (half-open)."""
        st = self.state(name)
        if st == BREAKER_CLOSED:
            return -1
        if st == BREAKER_OPEN:
            if now - self._opened_at.get(name, now) >= self.probe_after_s:
                self._state[name] = BREAKER_HALF_OPEN
                self._probes[name] = 0
                if self.tracer is not None:
                    self.tracer.span("breaker", now, replica=name,
                                     old=BREAKER_OPEN,
                                     new=BREAKER_HALF_OPEN)
                return self.probe_budget
            return 0
        return max(self.probe_budget - self._probes.get(name, 0), 0)

    def note_probe(self, name: str, n: int) -> None:
        if self.state(name) == BREAKER_HALF_OPEN:
            self._probes[name] = self._probes.get(name, 0) + n

    def observe(self, name: str, now: float, tokens_delta: int,
                had_work: bool, errors: int = 0) -> None:
        stalled = (had_work and tokens_delta <= 0) or errors > 0
        st = self.state(name)
        if st == BREAKER_HALF_OPEN:
            if had_work:             # probe outcome resolved
                if stalled:
                    self._state[name] = BREAKER_OPEN
                    self._opened_at[name] = now
                else:
                    self._state[name] = BREAKER_CLOSED
                    self._stall[name] = 0
                    self.rejoins += 1
                if self.tracer is not None:
                    self.tracer.span("breaker", now, replica=name,
                                     old=BREAKER_HALF_OPEN,
                                     new=self._state[name])
            return
        if st == BREAKER_OPEN:
            return
        if stalled:
            self._stall[name] = self._stall.get(name, 0) + 1
            if self._stall[name] >= self.stall_ticks:
                self._state[name] = BREAKER_OPEN
                self._opened_at[name] = now
                self.ejections += 1
                if self.tracer is not None:
                    self.tracer.span("breaker", now, replica=name,
                                     old=BREAKER_CLOSED, new=BREAKER_OPEN)
        else:
            self._stall[name] = 0

    def forget(self, name: str) -> None:
        """Replica retired: drop its breaker state."""
        for m in (self._state, self._stall, self._opened_at, self._probes):
            m.pop(name, None)
