"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; the four assigned
input shapes are ``ShapeConfig`` entries. ``REGISTRY`` maps ``--arch <id>``
strings to config factories; ``reduced()`` produces the family-preserving
small config used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    first_k_dense: int = 0  # deepseek: first layer(s) stay dense
    # dispatch-buffer sharding (see EXPERIMENTS.md §Perf):
    #   "local"  — buffer stays data-local/model-replicated; the expert
    #              einsum slices it per model rank; one explicit AG back.
    #   "expert" — buffer expert-sharded (GSPMD lowers the scatter to a
    #              replicated scatter + per-layer all-reduce: 100x wire).
    dispatch: str = "local"


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_width: int = 4
    d_inner: int = 0          # inner width of the SSM branch
    dt_rank: int = 0


@dataclass(frozen=True)
class XLSTMConfig:
    group_size: int = 8       # layers per super-block: (group_size-1) mLSTM + 1 sLSTM
    proj_factor_m: float = 2.0
    proj_factor_s: float = 4.0 / 3.0
    conv_width: int = 4


@dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 0
    enc_seq: int = 1500       # whisper audio frames after conv frontend (stubbed)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    mlp: str = "swiglu"       # swiglu | geglu | relu2 | none
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # attention locality: per-layer window override. None => full causal.
    sliding_window: Optional[int] = None
    global_every: int = 0     # if >0 with sliding_window: every k-th layer is global
    attn_chunk: Optional[int] = None   # llama4 iRoPE-style chunked attention
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[str] = None     # audio | vision (stubbed embeddings)
    frontend_seq: int = 0
    n_meta_tokens: int = 0             # hymba learnable meta tokens
    dtype: str = "bfloat16"
    # long_500k requires sub-quadratic attention; see DESIGN.md for skips.
    subquadratic: bool = False

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def reduced(self) -> "ArchConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        changes = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(self.n_heads, 1)) or 1),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            frontend_seq=16 if self.frontend_seq else 0,
            n_meta_tokens=4 if self.n_meta_tokens else 0,
            dtype="float32",
        )
        if self.moe is not None:
            changes["moe"] = replace(
                self.moe,
                n_routed=4,
                top_k=min(self.moe.top_k, 2),
                n_shared=min(self.moe.n_shared, 1),
                d_ff_expert=32 if self.moe.d_ff_expert else 0,
                first_k_dense=min(self.moe.first_k_dense, 1),
            )
        if self.ssm is not None:
            changes["ssm"] = replace(self.ssm, state_dim=8, d_inner=128, dt_rank=8)
        if self.xlstm is not None:
            changes["xlstm"] = replace(self.xlstm, group_size=2)
            changes["n_layers"] = 4  # 2 groups of (1 mLSTM + 1 sLSTM)
        if self.encdec is not None:
            changes["encdec"] = replace(self.encdec, n_enc_layers=2, enc_seq=16)
        if self.sliding_window is not None:
            changes["sliding_window"] = 8
        if self.attn_chunk is not None:
            changes["attn_chunk"] = 16
        return replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

# long_500k runs only for sub-quadratic archs (DESIGN.md §4).
ARCH_IDS = [
    "whisper-medium", "qwen2-7b", "yi-34b", "granite-20b", "minitron-8b",
    "llama4-scout-17b-a16e", "deepseek-moe-16b", "paligemma-3b",
    "xlstm-1.3b", "hymba-1.5b",
]

_REGISTRY = {}


def register(name):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # import sibling modules lazily so registration happens
        from repro import configs as _pkg  # noqa
        import importlib
        for mod in ("whisper_medium", "qwen2_7b", "yi_34b", "granite_20b",
                    "minitron_8b", "llama4_scout", "deepseek_moe_16b",
                    "paligemma_3b", "xlstm_1_3b", "hymba_1_5b"):
            importlib.import_module(f"repro.configs.{mod}")
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def shapes_for(cfg: ArchConfig):
    """The assigned shape cells for this arch (long_500k gated on subquadratic)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.subquadratic:
            continue
        out.append(s)
    return out
