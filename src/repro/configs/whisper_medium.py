"""whisper-medium [audio]: 24L enc + 24L dec, d_model=1024, 16H (kv=16),
d_ff=4096, vocab=51865. Conv audio frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, 1500, 1024). Encoder is bidirectional;
decoder is causal with cross-attention. [arXiv:2212.04356]

Adaptation notes: RoPE replaces whisper's learned/sinusoidal positions so the
decoder shares the substrate attention stack; see DESIGN.md.
"""
from repro.configs.base import ArchConfig, EncDecConfig, register


@register("whisper-medium")
def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-medium",
        family="encdec",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab=51865,
        mlp="geglu",
        norm_eps=1e-5,
        encdec=EncDecConfig(n_enc_layers=24, enc_seq=1500),
        frontend="audio",
        frontend_seq=1500,
    )
