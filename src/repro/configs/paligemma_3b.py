"""paligemma-3b [vlm]: 18L, d_model=2048, 8H (MQA kv=1), d_ff=16384,
vocab=257216. SigLIP vision frontend is a STUB: ``input_specs`` feeds
precomputed patch embeddings (B, 256, 2048) prepended to the text sequence.
Gemma decoder: GeGLU, RMSNorm, tied embeddings. [arXiv:2407.07726]"""
from repro.configs.base import ArchConfig, register


@register("paligemma-3b")
def config() -> ArchConfig:
    return ArchConfig(
        name="paligemma-3b",
        family="vlm",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab=257216,
        mlp="geglu",
        tie_embeddings=True,
        frontend="vision",
        frontend_seq=256,
    )
