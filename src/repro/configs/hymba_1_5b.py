"""hymba-1.5b [hybrid]: 32L, d_model=1600, 25 attn heads (GQA kv=5,
head_dim=64) in PARALLEL with mamba-style SSM heads (state=16, d_inner=3200),
d_ff=5504, vocab=32001. 128 learnable meta tokens prepended; sliding-window
(1024) attention everywhere except global layers {0, mid, last}.
SSM + SWA -> sub-quadratic -> long_500k runs. [arXiv:2411.13676]"""
from repro.configs.base import ArchConfig, SSMConfig, register


@register("hymba-1.5b")
def config() -> ArchConfig:
    return ArchConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab=32001,
        mlp="swiglu",
        sliding_window=1024,
        global_every=16,       # layers 0, 16, (31 handled as mid/last approx)
        n_meta_tokens=128,
        subquadratic=True,
        ssm=SSMConfig(state_dim=16, conv_width=4, d_inner=3200, dt_rank=100),
    )
