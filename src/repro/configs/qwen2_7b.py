"""qwen2-7b [dense]: 28L, d_model=3584, 28H (GQA kv=4), d_ff=18944,
vocab=152064. QKV bias, RoPE theta=1e6, SwiGLU, RMSNorm. [arXiv:2407.10671]"""
from repro.configs.base import ArchConfig, register


@register("qwen2-7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-7b",
        family="dense",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab=152064,
        mlp="swiglu",
        qkv_bias=True,
        rope_theta=1e6,
    )
