"""xlstm-1.3b [ssm]: 48 blocks, d_model=2048, 4 heads, d_ff=0 (projection
happens inside the mLSTM/sLSTM blocks), vocab=50304. Blocks are grouped as
7 mLSTM + 1 sLSTM per super-block (xLSTM[7:1]); recurrent state decode is
O(1) per token -> long_500k runs. [arXiv:2405.04517]"""
from repro.configs.base import ArchConfig, XLSTMConfig, register


@register("xlstm-1.3b")
def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        head_dim=512,          # d_model / n_heads inside the mLSTM cell
        d_ff=0,
        vocab=50304,
        mlp="none",
        subquadratic=True,
        xlstm=XLSTMConfig(group_size=8, proj_factor_m=2.0,
                          proj_factor_s=4.0 / 3.0, conv_width=4),
    )
