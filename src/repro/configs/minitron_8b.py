"""minitron-8b [dense]: 32L, d_model=4096, 32H (GQA kv=8), d_ff=16384,
vocab=256000. Pruned nemotron lineage -> squared-ReLU MLP (no gate).
[arXiv:2407.14679]"""
from repro.configs.base import ArchConfig, register


@register("minitron-8b")
def config() -> ArchConfig:
    return ArchConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=256000,
        mlp="relu2",
        norm_eps=1e-5,
    )
