"""deepseek-moe-16b [moe]: 28L, d_model=2048, 16H (kv=16), vocab=102400.
Fine-grained MoE: 2 shared + 64 routed experts, top-6, expert d_ff=1408.
First layer stays dense (d_ff = (top_k + n_shared) * 1408 = 11264,
approximating the paper's 10944). [arXiv:2401.06066]"""
from repro.configs.base import ArchConfig, MoEConfig, register


@register("deepseek-moe-16b")
def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab=102400,
        mlp="swiglu",
        moe=MoEConfig(n_routed=64, top_k=6, n_shared=2, d_ff_expert=1408,
                      capacity_factor=1.25, first_k_dense=1, dispatch="shard_map"),
    )
