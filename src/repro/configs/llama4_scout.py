"""llama4-scout-17b-a16e [moe]: 48L, d_model=5120, 40H (GQA kv=8),
d_ff=8192 per expert, vocab=202048, MoE 16 routed top-1 + 1 shared expert.
Chunked attention (iRoPE-style, 8k chunks) makes long-context decode
sub-quadratic -> long_500k runs for this arch.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.configs.base import ArchConfig, MoEConfig, register


@register("llama4-scout-17b-a16e")
def config() -> ArchConfig:
    return ArchConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=202048,
        mlp="swiglu",
        rope_theta=5e5,
        attn_chunk=8192,
        subquadratic=True,
        moe=MoEConfig(n_routed=16, top_k=1, n_shared=1, d_ff_expert=8192,
                      capacity_factor=1.25, dispatch="shard_map"),
    )
