"""AdamW with fp32 master weights, ZeRO-1 state sharding, grad clipping and a
warmup+cosine schedule. Pure pytree functions (no optax dependency)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params):
    # copy=True: with f32 params, astype would alias the param buffer and
    # break donation (same buffer donated twice as params AND master)
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    return {
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "master": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_init(abstract_params):
    return jax.eval_shape(init, abstract_params)


def zero1_spec(spec: P, shape, mesh) -> P:
    """Insert the ZeRO axis ("data") into the first unsharded, divisible dim
    of an optimizer-state tensor; no-op if "data" already used or nothing fits."""
    if mesh is None or "data" not in mesh.shape:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        if e is None:
            continue
        used.update(e if isinstance(e, tuple) else (e,))
    if "data" in used:
        return P(*entries)
    dsize = mesh.shape["data"]
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % dsize == 0 and dim >= dsize:
            entries[i] = "data"
            break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def opt_specs(param_spec_tree, abstract_params, mesh):
    """PartitionSpec tree for the optimizer state (mu/nu/master ZeRO-sharded)."""
    state_specs = jax.tree.map(
        lambda spec, p: zero1_spec(spec, p.shape, mesh),
        param_spec_tree, abstract_params)
    return {"mu": state_specs, "nu": state_specs, "master": state_specs,
            "step": P()}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply(grads, opt, params, cfg: AdamWConfig, grad_specs=None, mesh=None):
    """One AdamW step. Returns (new_params, new_opt, metrics)."""
    step = opt["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, master, p):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        upd = mhat / (jnp.sqrt(nhat) + cfg.eps)
        # decoupled weight decay (skip 1-d tensors: norms/biases)
        if master.ndim > 1:
            upd = upd + cfg.weight_decay * master
        master = master - lr * upd
        return mu, nu, master, master.astype(p.dtype)

    out = jax.tree.map(upd, grads, opt["mu"], opt["nu"], opt["master"], params)
    # unzip the 4-tuples
    mu = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    master = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda t: t[3], out, is_leaf=lambda t: isinstance(t, tuple))
    new_opt = {"mu": mu, "nu": nu, "master": master, "step": step}
    return new_params, new_opt, {"grad_norm": gnorm, "lr": lr}
