"""Gradient compression for cross-pod data parallelism.

int8 block-quantized all-reduce with error feedback (EF-SGD style): the
``pod`` axis crosses the slow inter-pod boundary (DCN/optical), so grads
are quantized to int8 (32x less wire than fp32, 4x less than bf16) before
the inter-pod reduction; quantization residual is carried in an error-
feedback buffer so the optimizer sees an unbiased-in-the-limit gradient.

``compressed_psum`` is the shard_map building block (quantize -> psum of
int32 accumulators -> dequantize); ``ef_compress`` is the mesh-free
functional core used by tests and by train drivers on small meshes.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x, block: int = 256):
    """Blockwise symmetric int8 quantization along the last axis."""
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), shape, pad


def dequantize_int8(q, scale, shape, pad):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def ef_compress(g, ef, block: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 round trip: returns (g_hat, new_ef)."""
    target = g + ef
    q, scale, shape, pad = quantize_int8(target, block)
    g_hat = dequantize_int8(q, scale, shape, pad)
    return g_hat, target - g_hat


def ef_compress_tree(grads, ef_tree, block: int = 256):
    out = jax.tree.map(lambda g, e: ef_compress(g, e, block), grads, ef_tree)
    g_hat = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return g_hat, new_ef


def init_ef(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(g, axis_name: str, block: int = 256, mean: bool = True):
    """Inside shard_map: int8-quantize locally, all-gather the int8 payload
    (+ fp32 block scales) over the slow axis, dequantize EXACTLY with each
    participant's own scale and sum locally.

    Wire: (g-1)/g x (1 B/elem + 4/block B scales) vs fp32 ring all-reduce
    2(g-1)/g x 4 B/elem => ~8x less inter-pod traffic. Exact arithmetic
    given the quantized payloads (the only loss is each sender's local
    quantization error — carried by the caller's error-feedback buffer)."""
    q, scale, shape, pad = quantize_int8(g, block)
    qs = jax.lax.all_gather(q, axis_name)          # (P, nblk, block) int8
    ss = jax.lax.all_gather(scale, axis_name)      # (P, nblk, 1) f32
    total = jnp.sum(qs.astype(jnp.float32) * ss, axis=0)
    flat = total.reshape(-1)
    if pad:
        flat = flat[:-pad]
    out = flat.reshape(shape)
    if mean:
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        out = out / n
    return out


def compressed_psum_ef(g, ef, axis_name: str, block: int = 256,
                       mean: bool = True):
    """Error-feedback variant: compresses (g + ef), returns the exact sum
    of the quantized payloads and the new local residual."""
    target = g.astype(jnp.float32) + ef
    q, scale, shape, pad = quantize_int8(target, block)
    local_dq = dequantize_int8(q, scale, shape, pad)
    new_ef = target - local_dq
    qs = jax.lax.all_gather(q, axis_name)
    ss = jax.lax.all_gather(scale, axis_name)
    total = jnp.sum(qs.astype(jnp.float32) * ss, axis=0)
    flat = total.reshape(-1)
    if pad:
        flat = flat[:-pad]
    out = flat.reshape(shape)
    if mean:
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        out = out / n
    return out, new_ef
