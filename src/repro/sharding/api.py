"""Sharding context: logical-axis -> mesh-axis resolution with divisibility
fallback.

Models annotate tensors with *logical* axes ("batch", "vocab", "qdim", ...).
``ShardCtx`` resolves them against the active mesh: a logical axis maps to a
tuple of candidate mesh axes; the longest prefix whose size product divides
the dim (and whose mesh axes are still unused in this spec) wins. This is
what makes one sharding ruleset work across all 10 archs (28 heads, 25 heads,
kv=1 ... nothing has to divide 16 except the merged dims, which always do).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> ordered mesh-axis candidates (joint sharding tuple)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "batch_data": ("data",),          # data-only (pod kept for grad hierarchy)
    "expert": ("model",),
    "vocab": ("model",),
    "qdim": ("model",),               # merged n_heads*head_dim
    "kvdim": ("model",),              # merged n_kv_heads*head_dim
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "d_model": (),                    # activations' feature dim: replicated
    "d_model_shard": ("model",),      # row-parallel weight input dim (unused by default)
    "seq": (),
    "seq_tp": ("model",),             # scheme-B attention: sequence over model
    # cache seq: prefers data+model jointly; when batch already took "data"
    # (decode_32k) the resolver falls back to model-only; when batch is 1
    # (long_500k) the cache spreads over all 256 chips.
    "cache_seq": ("data", "model"),
    "frames": (),
    "state": (),
    "zero": ("data",),                # ZeRO-1 optimizer-state sharding
    "inner": ("model",),              # SSM/xLSTM inner projection dim
    "replicated": (),
}


@dataclasses.dataclass
class ShardCtx:
    mesh: Optional[Mesh] = None
    rules: dict = dataclasses.field(default_factory=lambda: dict(DEFAULT_RULES))

    def axis_size(self, name: str) -> int:
        if self.mesh is None or name not in self.mesh.shape:
            return 1
        return self.mesh.shape[name]

    def spec(self, axes: Sequence[Optional[str]], dims: Sequence[int]) -> P:
        """Resolve logical axes for a tensor of shape ``dims`` to a PartitionSpec.

        ``axes`` may be a tuple of logical names or a PartitionSpec carrying
        logical names (models annotate with ``P("vocab", None)`` so the axes
        pytrees have leaf semantics). Shorter ``axes`` are right-padded.
        """
        if self.mesh is None:
            return P()
        axes = tuple(axes) + (None,) * (len(dims) - len(tuple(axes)))
        used = set()
        out = []
        for ax, dim in zip(axes, dims):
            if ax is None:
                out.append(None)
                continue
            cands = self.rules.get(ax, ())
            cands = tuple(a for a in cands if a in self.mesh.shape and a not in used)
            picked: Tuple[str, ...] = ()
            # longest prefix of candidates whose product divides the dim
            for k in range(len(cands), 0, -1):
                prefix = cands[:k]
                size = 1
                for a in prefix:
                    size *= self.mesh.shape[a]
                if size > 1 and dim % size == 0:
                    picked = prefix
                    break
            if picked:
                used.update(picked)
                out.append(picked if len(picked) > 1 else picked[0])
            else:
                out.append(None)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, axes, dims) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(axes, dims))

    def constrain(self, x, *axes):
        """with_sharding_constraint against resolved logical axes (no-op w/o mesh)."""
        if self.mesh is None:
            return x
        spec = self.spec(axes, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


def tree_specs(ctx: ShardCtx, abstract_tree, axes_tree):
    """Map parallel (ShapeDtypeStruct, logical-axes-as-PartitionSpec) pytrees
    to a concrete PartitionSpec tree. Axes leaves are ``P(<logical>, ...)``
    (PartitionSpec is an unregistered pytree type, i.e. a leaf)."""
    return jax.tree.map(lambda sds, axes: ctx.spec(axes, sds.shape),
                        abstract_tree, axes_tree)


def tree_shardings(ctx: ShardCtx, abstract_tree, axes_tree):
    return jax.tree.map(
        lambda sds, axes: NamedSharding(ctx.mesh, ctx.spec(axes, sds.shape)),
        abstract_tree, axes_tree)
