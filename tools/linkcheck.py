"""Intra-repo markdown link checker (the CI docs job).

Scans markdown files for ``[text](target)`` links and verifies that every
relative target exists on disk, so documented paths can't silently rot.
External links (http/https/mailto) and pure in-page anchors are skipped;
``#fragment`` suffixes on file targets are stripped before checking.

Usage: ``python tools/linkcheck.py [files-or-dirs ...]``
(default: README.md and docs/). Exits 1 listing every broken link.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files(args: list) -> list:
    if not args:
        args = [ROOT / "README.md", ROOT / "docs"]
    out = []
    for a in args:
        p = pathlib.Path(a)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.md")))
        else:
            out.append(p)
    return out


def check(path: pathlib.Path) -> list:
    broken = []
    for n, line in enumerate(path.read_text().splitlines(), 1):
        for target in LINK.findall(line):
            if target.startswith(SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (path.parent / rel).exists():
                broken.append(f"{path.relative_to(ROOT)}:{n}: {target}")
    return broken


def main(argv=None) -> int:
    broken = []
    files = md_files(list(argv if argv is not None else sys.argv[1:]))
    for f in files:
        broken.extend(check(f))
    if broken:
        print(f"{len(broken)} broken intra-repo link(s):")
        for b in broken:
            print(f"  {b}")
        return 1
    print(f"linkcheck: {len(files)} file(s) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
