"""Render a flight-recorder dump (``serve.py --trace-out`` or an
incident bundle) as a per-request timeline, and assert span chains.

Importable (the obs tests and CI job use the helpers) and a CLI:

    python tools/tracedump.py trace.json                 # all requests
    python tools/tracedump.py trace.json --rid 7         # one request
    python tools/tracedump.py trace.json \
        --require-chain enqueue,admit,decode,retire      # exit 2 on miss

``--require-chain`` passes when at least one rid's span chain contains
the given names as a subsequence (in order, gaps allowed) — the smoke
gate that a request's whole life is reconstructable from the dump.

No repro imports: works on any machine with just the JSON file.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence


def spans_of(bundle: dict) -> List[dict]:
    """The span list of a flight-recorder dump or incident bundle."""
    return bundle.get("spans", [])


def rid_spans(spans: Sequence[dict], rid: int) -> List[dict]:
    """One rid's spans in emission order: spans stamped with the rid
    directly plus block spans (prefill/decode) listing it in
    ``attrs.rids``."""
    out = [s for s in spans
           if s.get("rid") == rid or rid in (s.get("attrs", {})
                                             .get("rids") or ())]
    out.sort(key=lambda s: s.get("seq", 0))
    return out


def chain_names(spans: Sequence[dict], rid: int) -> List[str]:
    return [s["name"] for s in rid_spans(spans, rid)]


def all_rids(spans: Sequence[dict]) -> List[int]:
    seen = set()
    for s in spans:
        if s.get("rid"):
            seen.add(s["rid"])
        seen.update(s.get("attrs", {}).get("rids") or ())
    return sorted(seen)


def has_subsequence(names: Sequence[str], want: Sequence[str]) -> bool:
    """True when ``want`` appears in ``names`` in order (gaps allowed)."""
    it = iter(names)
    return all(w in it for w in want)


def find_chain(bundle: dict, want: Sequence[str]) -> Optional[int]:
    """First rid whose span chain contains ``want`` as a subsequence."""
    spans = spans_of(bundle)
    for rid in all_rids(spans):
        if has_subsequence(chain_names(spans, rid), want):
            return rid
    return None


def render(bundle: dict, rid: Optional[int] = None) -> str:
    """Human timeline: one line per span, grouped per rid (or one rid)."""
    spans = spans_of(bundle)
    lines = []
    rids = [rid] if rid is not None else all_rids(spans)
    for r in rids:
        chain = rid_spans(spans, r)
        if not chain:
            lines.append(f"rid {r}: no spans")
            continue
        t0, t1 = chain[0]["t"], chain[-1]["t"]
        incs = {s.get("inc", 0) for s in chain}
        lines.append(f"rid {r}: {len(chain)} spans over "
                     f"[{t0:g}, {t1:g}]s, incarnations={sorted(incs)}")
        for s in chain:
            attrs = {k: v for k, v in s.get("attrs", {}).items()
                     if k != "rids"}
            extra = (" " + " ".join(f"{k}={v}" for k, v in attrs.items())
                     if attrs else "")
            lines.append(f"  t={s['t']:>8g}  inc={s.get('inc', 0)}  "
                         f"{s['name']:<16}{extra}")
    ctl = [s for s in spans if not s.get("rid")
           and not s.get("attrs", {}).get("rids")]
    if rid is None and ctl:
        lines.append(f"control plane: {len(ctl)} spans")
        counts: Dict[str, int] = {}
        for s in ctl:
            counts[s["name"]] = counts.get(s["name"], 0) + 1
        for name in sorted(counts):
            lines.append(f"  {name}: {counts[name]}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("bundle", help="trace/incident JSON file")
    ap.add_argument("--rid", type=int, default=None,
                    help="render only this request")
    ap.add_argument("--require-chain", default="",
                    help="comma-separated span names; exit 2 unless some"
                         " rid's chain contains them in order")
    args = ap.parse_args(argv)
    with open(args.bundle) as fh:
        bundle = json.load(fh)
    if args.require_chain:
        want = [w.strip() for w in args.require_chain.split(",") if w.strip()]
        rid = find_chain(bundle, want)
        if rid is None:
            print(f"FAIL: no rid with span chain {want}", file=sys.stderr)
            return 2
        print(f"chain {want} reconstructs for rid {rid}:")
        print(render(bundle, rid))
        return 0
    print(render(bundle, args.rid))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
