"""Metric hygiene lint (CI: obs-smoke job).

Two checks:

1. Inventory: every ``ersap_*`` metric name appearing in ``src/`` must
   be documented in the metric-inventory table of
   ``docs/ARCHITECTURE.md`` — new metrics cannot land undocumented.
2. ``--exposition FILE``: parse a ``serve.py --metrics-out`` dump with
   a strict standalone parser (no repro imports, so the docs job can
   run this without jax) and fail on malformed lines, then re-run the
   inventory check against the *emitted* series names too.

Exit 1 on any finding; prints one line per violation.
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
METRIC_RE = re.compile(r"\bersap_[a-z0-9_]+")
# derived series suffixes the exposition format appends to histograms
DERIVED = ("_bucket", "_sum", "_count")


def src_metric_names() -> dict:
    """{metric name: first 'file:line' where it appears} across src/."""
    out = {}
    for path in sorted(ROOT.glob("src/**/*.py")):
        for i, line in enumerate(path.read_text().splitlines(), 1):
            for name in METRIC_RE.findall(line):
                out.setdefault(name, f"{path.relative_to(ROOT)}:{i}")
    return out


def documented_names() -> set:
    doc = ROOT / "docs" / "ARCHITECTURE.md"
    if not doc.exists():
        return set()
    return set(METRIC_RE.findall(doc.read_text()))


def strip_derived(name: str) -> str:
    for suf in DERIVED:
        if name.endswith(suf):
            return name[:-len(suf)]
    return name


def parse_exposition_file(path: str) -> dict:
    """Standalone Prometheus-text parser: {series: value}, raising
    ValueError on any malformed line."""
    out = {}
    text = pathlib.Path(path).read_text()
    for i, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = re.match(r'^([A-Za-z_][A-Za-z0-9_]*)'
                     r'(\{[^{}]*\})?\s+(\S+)$', line)
        if not m:
            raise ValueError(f"{path}:{i}: malformed exposition line: "
                             f"{line!r}")
        name, labels, val = m.groups()
        try:
            out[name + (labels or "")] = float(val.replace("+Inf", "inf"))
        except ValueError:
            raise ValueError(f"{path}:{i}: bad sample value {val!r}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--exposition", default="",
                    help="also parse+lint a --metrics-out dump")
    args = ap.parse_args(argv)
    failures = []
    documented = documented_names()
    if not documented:
        failures.append("docs/ARCHITECTURE.md documents no ersap_* metrics"
                        " (missing inventory section?)")
    for name, where in sorted(src_metric_names().items()):
        if name not in documented:
            failures.append(f"{where}: metric {name} is not documented in"
                            f" docs/ARCHITECTURE.md")
    if args.exposition:
        try:
            series = parse_exposition_file(args.exposition)
        except ValueError as e:
            failures.append(str(e))
            series = {}
        bases = {strip_derived(re.split(r"\{", s, 1)[0]) for s in series}
        for base in sorted(b for b in bases if b.startswith("ersap_")):
            if base not in documented:
                failures.append(f"{args.exposition}: emitted metric {base}"
                                f" is not documented in docs/ARCHITECTURE.md")
        if series:
            print(f"[metriclint] {args.exposition}: {len(series)} series"
                  f" parsed clean")
    for f in failures:
        print(f"[metriclint] FAIL: {f}", file=sys.stderr)
    if not failures:
        print(f"[metriclint] OK: {len(documented)} documented metrics,"
              f" src inventory clean")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
